import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real jitted program (train_step for training
shapes, prefill/serve_step for inference shapes) with full-size
ShapeDtypeStruct inputs and production shardings, compiles it, and records:

  * memory_analysis()      — per-device bytes (proves the cell fits HBM)
  * cost_analysis()        — per-device HLO FLOPs / bytes accessed
  * collective byte totals — parsed from the post-SPMD HLO text, per op kind
  * lowering/compile wall times

Results are cached as JSON under experiments/dryrun/ (one file per cell);
repro.roofline.analysis consumes them for EXPERIMENTS.md §Roofline.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --all [--mesh both]
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-12b \
      --shape train_4k --mesh single --policy qm
"""
import argparse
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs, policies
from repro.configs.base import SHAPES, cells_for, input_specs
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.model import DecoderModel
from repro.roofline import hlo_collectives, jaxpr_cost
from repro.serve import engine
from repro.train import step as train_step_mod
from repro.train.state import TrainState

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str):
    """Sum result-operand sizes of every collective op, by kind."""
    stats = {k: {"count": 0, "bytes": 0} for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(", line)
        if not m:
            continue
        type_str, op = m.groups()
        base = op
        for suffix in ("-start", "-done"):
            if base.endswith(suffix):
                base = base[: -len(suffix)]
        if base not in _COLLECTIVES:
            continue
        if op.endswith("-done"):
            continue  # counted at -start
        stats[base]["count"] += 1
        stats[base]["bytes"] += _shape_bytes(type_str)
    stats["total_bytes"] = sum(
        v["bytes"] for k, v in stats.items() if isinstance(v, dict))
    return stats


def _microbatches_for(shape) -> int:
    return 4 if shape.kind == "train" else 1


def _policy_from(name: str) -> policies.Policy:
    """Any registry policy (or '+'-composition); sfp8 realized stash."""
    return policies.get(name, container="sfp8")


def build_cell(arch_name: str, shape_name: str, multi_pod: bool,
               policy_name: str, layout: str = "tp",
               num_microbatches: int = None):
    """Returns (jitted_fn, arg_shapes tuple) ready to lower."""
    cfg = configs.get(arch_name)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = shd.rules_for(mesh, layout=layout)
    policy = _policy_from(policy_name)
    model = DecoderModel(cfg, policy, mesh=mesh, rules=rules)

    param_axes = model.param_axes()
    param_sh = shd.tree_shardings(mesh, param_axes, rules)
    repl = shd.replicated(mesh)
    specs = input_specs(cfg, shape)
    batch_p = shd.batch_specs(rules, shape.kind, "cond_embeddings" in specs)
    batch_sh = {k: NamedSharding(mesh, batch_p[k]) for k in specs}

    if shape.kind == "train":
        nm = (num_microbatches if num_microbatches is not None
              else (1 if layout == "fsdp" else _microbatches_for(shape)))
        tc = train_step_mod.TrainConfig(num_microbatches=nm,
                                        param_shardings=param_sh)
        fn = train_step_mod.make_train_step(model, tc)
        state_shapes = jax.eval_shape(
            lambda k: train_step_mod.init_state(model, k, tc),
            jax.random.PRNGKey(0))
        state_sh = TrainState(
            params=param_sh,
            opt=state_shapes.opt._replace(m=param_sh, v=param_sh, count=repl),
            pstate=jax.tree.map(lambda _: repl, state_shapes.pstate),
            step=repl, rng=repl, grad_residual=None)
        state_sh = shd.refine_shardings(state_shapes, state_sh, mesh)
        batch_sh = shd.refine_shardings(specs, batch_sh, mesh)
        jfn = jax.jit(fn, in_shardings=(state_sh, batch_sh),
                      donate_argnums=(0,))
        return jfn, (state_shapes, specs), mesh

    if shape.kind == "prefill":
        fn = engine.make_prefill_step(model, max_len=shape.seq_len)
        params_shapes = model.param_shapes()
        cax = engine.cache_axes(model, shape.global_batch, shape.seq_len)
        cache_sh = shd.tree_shardings(mesh, cax, rules)
        args = [params_shapes, specs["tokens"]]
        in_sh = [param_sh, batch_sh["tokens"]]
        if "cond_embeddings" in specs:
            args.append(specs["cond_embeddings"])
            in_sh.append(batch_sh["cond_embeddings"])
        jfn = jax.jit(fn, in_shardings=tuple(in_sh),
                      out_shardings=(NamedSharding(
                          mesh, batch_p["tokens"]), cache_sh))
        return jfn, tuple(args), mesh

    # decode
    fn = engine.make_serve_step(model)
    params_shapes = model.param_shapes()
    cache_shapes = model.init_cache(shape.global_batch, shape.seq_len,
                                    spec_only=True)
    cax = engine.cache_axes(model, shape.global_batch, shape.seq_len)
    cache_sh = shd.tree_shardings(mesh, cax, rules)
    cache_sh = shd.refine_shardings(cache_shapes, cache_sh, mesh)
    tok_sh = shd.refine_shardings(specs["tokens"], batch_sh["tokens"], mesh)
    pos = jax.ShapeDtypeStruct((), jnp.int32)
    jfn = jax.jit(fn, in_shardings=(param_sh, cache_sh, tok_sh, repl),
                  donate_argnums=(1,))
    return jfn, (params_shapes, cache_shapes, specs["tokens"], pos), mesh


def run_cell(arch_name: str, shape_name: str, mesh_kind: str,
             policy_name: str, out_dir: Path, force: bool = False,
             layout: str = "tp", num_microbatches=None):
    tag = f"{arch_name}__{shape_name}__{mesh_kind}__{policy_name}"
    if layout != "tp":
        tag += f"__{layout}"
    out_file = out_dir / f"{tag}.json"
    if out_file.exists() and not force:
        print(f"[skip] {tag} (cached)")
        return json.loads(out_file.read_text())

    print(f"[cell] {tag} ...", flush=True)
    multi_pod = mesh_kind == "multi"
    record = {"arch": arch_name, "shape": shape_name, "mesh": mesh_kind,
              "policy": policy_name, "layout": layout, "ok": False}
    t0 = time.time()
    try:
        jfn, args, mesh = build_cell(arch_name, shape_name, multi_pod,
                                     policy_name, layout=layout,
                                     num_microbatches=num_microbatches)
        with mesh:
            t1 = time.time()
            lowered = jfn.lower(*args)
            t2 = time.time()
            compiled = lowered.compile()
            t3 = time.time()

        record["lower_s"] = round(t2 - t1, 2)
        record["compile_s"] = round(t3 - t2, 2)
        record["n_devices"] = 512 if multi_pod else 256

        try:
            ca = compiled.cost_analysis()
            if isinstance(ca, list):  # older jax: one dict per device
                ca = ca[0]
            record["cost_analysis"] = {
                k: float(v) for k, v in ca.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "bytes accessed0{}", "bytes accessed1{}",
                 "bytes accessedout{}", "optimal_seconds")}
        except Exception as e:  # pragma: no cover
            record["cost_analysis_error"] = str(e)

        try:
            ma = compiled.memory_analysis()
            if ma is not None:
                record["memory_analysis"] = {
                    a: int(getattr(ma, a)) for a in (
                        "argument_size_in_bytes", "output_size_in_bytes",
                        "temp_size_in_bytes", "alias_size_in_bytes",
                        "generated_code_size_in_bytes")
                    if hasattr(ma, a)}
        except Exception as e:  # pragma: no cover
            record["memory_analysis_error"] = str(e)

        try:
            hlo = compiled.as_text()
        except Exception:
            hlo = lowered.as_text()
        record["collectives"] = parse_collectives(hlo)
        record["collectives_trip_weighted"] = hlo_collectives.parse(hlo)
        record["hlo_bytes"] = len(hlo)

        # Jaxpr-level global flops/bytes with exact scan trip counts (the
        # CPU backend's cost_analysis does not unroll while bodies —
        # EXPERIMENTS.md §Roofline).
        try:
            t4 = time.time()
            record["jaxpr_cost"] = jaxpr_cost.estimate(jfn, *args)
            record["jaxpr_cost_s"] = round(time.time() - t4, 2)
        except Exception as e:  # pragma: no cover
            record["jaxpr_cost_error"] = str(e)
        record["ok"] = True
        print(f"  ok in {time.time() - t0:.1f}s "
              f"(lower {record['lower_s']}s, compile {record['compile_s']}s)",
              flush=True)
    except Exception as e:
        record["error"] = f"{type(e).__name__}: {e}"
        record["traceback"] = traceback.format_exc()[-4000:]
        print(f"  FAILED: {record['error']}", flush=True)

    out_dir.mkdir(parents=True, exist_ok=True)
    out_file.write_text(json.dumps(record, indent=2))
    return record


def all_cells(mesh_kinds, policy):
    """Cells of the production matrix. ``policy`` may be a comma list
    (e.g. ``qm,qm+qe,bitwave``): every policy gets its own cell per
    (arch, shape, mesh) point, so composed policies are first-class
    members of the matrix rather than a side experiment."""
    for cfg in configs.ASSIGNED:
        for shape in cells_for(cfg):
            for mk in mesh_kinds:
                for pol in policy.split(","):
                    yield cfg.name, shape.name, mk, pol.strip()


def summarize_hlo_vs(results, baseline_policy: str = "qm"):
    """Compare compiled-HLO sizes of each policy against ``baseline_policy``
    per (arch, shape, mesh) point — the cost of a composed policy's extra
    quantization machinery in program size."""
    base = {(r["arch"], r["shape"], r["mesh"]): r["hlo_bytes"]
            for r in results
            if r.get("ok") and r["policy"] == baseline_policy
            and "hlo_bytes" in r}
    rows = []
    for r in results:
        if not r.get("ok") or "hlo_bytes" not in r:
            continue
        key = (r["arch"], r["shape"], r["mesh"])
        if r["policy"] == baseline_policy or key not in base:
            continue
        rows.append({
            "arch": key[0], "shape": key[1], "mesh": key[2],
            "policy": r["policy"], "hlo_bytes": r["hlo_bytes"],
            f"vs_{baseline_policy}": r["hlo_bytes"] / base[key],
        })
    return rows


def _print_hlo_rows(results, baseline_policy: str = "qm"):
    for row in summarize_hlo_vs(results, baseline_policy):
        print(f"  hlo {row['arch']} {row['shape']} {row['mesh']} "
              f"{row['policy']}: {row['hlo_bytes']} bytes "
              f"({row[f'vs_{baseline_policy}']:.2f}x {baseline_policy})")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--policy", default="qm",
                    metavar="NAME[+NAME...][,NAME...]",
                    help="precision policy from the registry "
                         f"({'/'.join(policies.names())}), composable "
                         "with '+' and comma-separable into multiple "
                         "matrix cells, e.g. qm,qm+qe,bitwave")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--layout", default="tp", choices=["tp", "fsdp"])
    ap.add_argument("--microbatches", type=int, default=None)
    ap.add_argument("--out", default=str(OUT_DIR))
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    out_dir = Path(args.out)
    mesh_kinds = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.list:
        for cell in all_cells(mesh_kinds, args.policy):
            print("  ".join(cell))
        return

    if args.all:
        results = [run_cell(*cell, out_dir, args.force, layout=args.layout,
                            num_microbatches=args.microbatches)
                   for cell in all_cells(mesh_kinds, args.policy)]
        ok = sum(r["ok"] for r in results)
        print(f"\n== {ok}/{len(results)} cells compiled ==")
        _print_hlo_rows(results)
        if ok < len(results):
            for r in results:
                if not r["ok"]:
                    print(f"  FAIL {r['arch']} {r['shape']} {r['mesh']}: "
                          f"{r.get('error')}")
            raise SystemExit(1)
        return

    assert args.arch and args.shape, "--arch/--shape or --all required"
    results = []
    for mk in mesh_kinds:
        for pol in args.policy.split(","):
            r = run_cell(args.arch, args.shape, mk, pol.strip(), out_dir,
                         args.force, layout=args.layout,
                         num_microbatches=args.microbatches)
            results.append(r)
            if r["ok"]:
                print(json.dumps({k: r[k] for k in
                                  ("cost_analysis", "memory_analysis",
                                   "collectives") if k in r}, indent=2))
    _print_hlo_rows(results)


if __name__ == "__main__":
    main()
