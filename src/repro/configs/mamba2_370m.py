"""mamba2-370m [ssm] — SSD (state-space duality), attention-free.

[arXiv:2405.21060; unverified] 48L, d_model=1024, vocab=50280,
ssm_state=128.
"""
from repro.configs.base import ArchConfig, SSD, register

MAMBA2_370M = register(ArchConfig(
    name="mamba2-370m",
    family="ssm",
    n_layers=48,
    d_model=1024,
    n_heads=0,
    n_kv_heads=0,
    d_ff=0,
    vocab=50280,
    period=(SSD,),
    ssm_state=128,
    ssm_head_dim=64,
    ssm_expand=2,
    ssm_chunk=128,
    ssm_groups=1,
    conv_width=4,
    source="arXiv:2405.21060 (Mamba-2); assignment spec",
))
