"""musicgen-large [audio] — decoder-only over EnCodec tokens.

[arXiv:2306.05284; hf] 48L, d_model=2048, 32H (kv=32), d_ff=8192,
vocab=2048. Backbone only: the EnCodec/text-conditioning frontend is a
stub — input_specs() provides precomputed conditioning frame embeddings
consumed as a fully-visible prefix (prefix-LM).
"""
from repro.configs.base import ArchConfig, GLOBAL, register

MUSICGEN_LARGE = register(ArchConfig(
    name="musicgen-large",
    family="audio",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=8192,
    vocab=2048,
    period=(GLOBAL,),
    act="gelu",
    glu=False,
    prefix_tokens=64,
    tie_embeddings=False,
    source="arXiv:2306.05284 (MusicGen); assignment spec",
))
