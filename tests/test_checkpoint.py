import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "nested": {"b": jnp.arange(10, dtype=jnp.int32),
                       "c": jnp.ones((4,), jnp.bfloat16)}}


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree()
    mgr.save(3, t)
    back = mgr.restore(3, jax.tree.map(lambda x: jnp.zeros_like(x), t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_then_wait(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    t = _tree(1)
    mgr.save(1, t, blocking=False)
    mgr.wait()
    assert mgr.latest_step() == 1


def test_gc_keeps_last_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    t = _tree(2)
    for s in (1, 2, 3, 4):
        mgr.save(s, t)
    assert mgr.all_steps() == [3, 4]


def test_atomic_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(7, _tree(3))
    names = [p.name for p in tmp_path.iterdir()]
    assert names == ["step_00000007"]


def test_restore_shape_mismatch_raises(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"a": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        mgr.restore(1, {"a": jnp.zeros((5,))})


def test_compressed_checkpoint_truncates_mantissas(tmp_path):
    mgr = CheckpointManager(str(tmp_path), compress_bits=4)
    t = {"w": jax.random.normal(jax.random.PRNGKey(0), (32, 32))}
    mgr.save(1, t)
    back = mgr.restore(1, t)
    from repro.core import containers as C
    np.testing.assert_array_equal(
        np.asarray(back["w"]), np.asarray(C.truncate_mantissa(t["w"], 4)))
    err = float(jnp.max(jnp.abs(back["w"] - t["w"])))
    assert 0 < err < 0.25


def test_legacy_compress_bits_leaves_bf16_raw(tmp_path):
    """compress_bits-only construction keeps the historical behaviour:
    only float32 leaves are quantized; bf16 leaves restore bit-exact."""
    mgr = CheckpointManager(str(tmp_path), compress_bits=4)
    t = {"wb": jax.random.normal(jax.random.PRNGKey(0), (32, 128)
                                 ).astype(jnp.bfloat16),
         "wf": jax.random.normal(jax.random.PRNGKey(1), (32, 32))}
    mgr.save(1, t)
    back = mgr.restore(1, t)
    np.testing.assert_array_equal(np.asarray(back["wb"]).view(np.uint16),
                                  np.asarray(t["wb"]).view(np.uint16))
    assert float(jnp.max(jnp.abs(back["wf"] - t["wf"]))) > 0  # fp32 truncated


def test_gecko8_checkpoint_lossless_bf16_and_never_silently_lossy(tmp_path):
    """Without explicit compress_bits, a codec may only compress leaves it
    round-trips bit-exactly: gecko8 compresses bf16 (lossless) but must
    leave fp32 untouched rather than silently dropping mantissa bits."""
    mgr = CheckpointManager(str(tmp_path), compress_codec="gecko8")
    t = {"wb": jax.random.normal(jax.random.PRNGKey(0), (64, 128)
                                 ).astype(jnp.bfloat16),
         "wf": jax.random.normal(jax.random.PRNGKey(1), (32, 32))}
    mgr.save(1, t)
    back = mgr.restore(1, t)
    np.testing.assert_array_equal(np.asarray(back["wb"]).view(np.uint16),
                                  np.asarray(t["wb"]).view(np.uint16))
    np.testing.assert_array_equal(np.asarray(back["wf"]), np.asarray(t["wf"]))
    import json
    step = tmp_path / "step_00000001"
    manifest = json.loads((step / "manifest.json").read_text())
    by_name = {e["name"]: e for e in manifest["leaves"]}
    assert by_name["['wb']"]["codec"] == "gecko8"
    assert "codec" not in by_name["['wf']"]
