"""Layer-level correctness: chunked attention vs oracle, SSD vs naive
recurrence, RG-LRU scan vs stepwise decode, MoE invariants."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.configs.base import reduced
from repro.kernels import ref
from repro.models import attention, common, mamba2, moe, rglru


def _cfg(name, **kw):
    cfg = reduced(configs.get(name))
    return dataclasses.replace(cfg, dtype="float32", **kw)


def test_chunked_global_attention_matches_oracle():
    cfg = _cfg("mistral-large-123b")
    p = common.ParamFactory("params", jax.random.PRNGKey(0), jnp.float32)
    params = attention.attn_init(p, cfg)
    B, S = 2, 512  # > 2*chunk forces the chunked path with chunk=128
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model),
                          jnp.float32) * 0.3
    got = attention.attention_train(params, h, cfg, kind="global",
                                    positions=jnp.arange(S), chunk=128)
    q, k, v = attention._project_qkv(params, h, cfg, jnp.arange(S))
    want = ref.attention(q, k, v, causal=True, softcap=cfg.attn_softcap)
    want = want.reshape(B, S, -1) @ params["wo"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def test_chunked_local_attention_matches_oracle():
    cfg = dataclasses.replace(_cfg("gemma2-2b"), window=96)
    p = common.ParamFactory("params", jax.random.PRNGKey(0), jnp.float32)
    params = attention.attn_init(p, cfg)
    B, S = 1, 512
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.3
    got = attention.attention_train(params, h, cfg, kind="local",
                                    positions=jnp.arange(S), chunk=128)
    q, k, v = attention._project_qkv(params, h, cfg, jnp.arange(S))
    want = ref.attention(q, k, v, causal=True, window=96,
                         softcap=cfg.attn_softcap)
    want = want.reshape(B, S, -1) @ params["wo"]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-4)


def _naive_ssd(params, h, cfg):
    """Direct per-step recurrence: the ground truth for chunked SSD."""
    x, z, Bp, Cp, dt, A, _ = mamba2._projections(params, h, cfg)
    B, S, H, P = x.shape
    N = cfg.ssm_state
    xf = np.asarray(x, np.float64)
    Bf = np.asarray(Bp, np.float64)
    Cf = np.asarray(Cp, np.float64)
    dtf = np.asarray(dt, np.float64)
    Af = np.asarray(A, np.float64)
    y = np.zeros((B, S, H, P))
    state = np.zeros((B, H, N, P))
    for t in range(S):
        decay = np.exp(dtf[:, t] * Af[None, :])  # (B, H)
        upd = np.einsum("bhn,bhp->bhnp", Bf[:, t],
                        xf[:, t] * dtf[:, t][..., None])
        state = state * decay[:, :, None, None] + upd
        y[:, t] = np.einsum("bhn,bhnp->bhp", Cf[:, t], state)
    y += xf * np.asarray(params["D"], np.float64)[None, None, :, None]
    y = jnp.asarray(y.reshape(B, S, H * P), jnp.float32)
    y = common.rmsnorm(params["norm"],
                       y * jax.nn.silu(z.astype(jnp.float32)))
    return y @ params["w_out"]


def test_ssd_chunked_matches_naive_recurrence():
    cfg = _cfg("mamba2-370m")
    p = common.ParamFactory("params", jax.random.PRNGKey(0), jnp.float32)
    params = mamba2.ssd_init(p, cfg)
    B, S = 2, 64  # 4 chunks of 16
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    got = mamba2.ssd_forward(params, h, cfg)
    want = _naive_ssd(params, h, cfg)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.slow
def test_ssd_prefill_state_matches_decode_continuation():
    cfg = _cfg("mamba2-370m")
    p = common.ParamFactory("params", jax.random.PRNGKey(0), jnp.float32)
    params = mamba2.ssd_init(p, cfg)
    B, S = 1, 32
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S + 1, cfg.d_model)) * 0.5
    # full-sequence output at position S
    full = mamba2.ssd_forward(params, h, cfg)
    # prefill S tokens then decode one
    out, cache = mamba2.ssd_forward(params, h[:, :S], cfg, return_cache=True)
    step, _ = mamba2.ssd_decode(params, h[:, S:S + 1], cache, cfg)
    np.testing.assert_allclose(np.asarray(step[:, 0]), np.asarray(full[:, S]),
                               atol=2e-3, rtol=2e-3)


@pytest.mark.slow
def test_rglru_scan_matches_stepwise_decode():
    cfg = _cfg("recurrentgemma-9b")
    p = common.ParamFactory("params", jax.random.PRNGKey(0), jnp.float32)
    params = rglru.rglru_init(p, cfg)
    B, S = 2, 24
    h = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.5
    full = rglru.rglru_forward(params, h, cfg)
    cache = rglru.lru_cache_init(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        o, cache = rglru.rglru_decode(params, h[:, t:t + 1], cache, cfg)
        outs.append(o)
    step = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(step), np.asarray(full),
                               atol=2e-4, rtol=2e-4)


def test_rglru_prefill_cache_continues():
    cfg = _cfg("recurrentgemma-9b")
    p = common.ParamFactory("params", jax.random.PRNGKey(0), jnp.float32)
    params = rglru.rglru_init(p, cfg)
    B, S = 1, 16
    h = jax.random.normal(jax.random.PRNGKey(2), (B, S + 1, cfg.d_model)) * 0.5
    full = rglru.rglru_forward(params, h, cfg)
    _, cache = rglru.rglru_forward(params, h[:, :S], cfg, return_cache=True)
    step, _ = rglru.rglru_decode(params, h[:, S:S + 1], cache, cfg)
    np.testing.assert_allclose(np.asarray(step[:, 0]), np.asarray(full[:, S]),
                               atol=2e-4, rtol=2e-4)


def test_moe_forward_shapes_and_aux():
    cfg = _cfg("olmoe-1b-7b")
    p = common.ParamFactory("params", jax.random.PRNGKey(0), jnp.float32)
    params = moe.moe_init(p, cfg)
    h = jax.random.normal(jax.random.PRNGKey(1), (2, 32, cfg.d_model)) * 0.5
    out, aux = moe.moe_forward(params, h, cfg)
    assert out.shape == h.shape
    assert float(aux["moe_lb_loss"]) >= 1.0 - 1e-3  # E * sum(me*ce) >= 1
    assert 0.0 <= float(aux["moe_drop_frac"]) < 0.5


def test_moe_capacity_drops_when_unbalanced():
    cfg = dataclasses.replace(_cfg("olmoe-1b-7b"), capacity_factor=0.5)
    p = common.ParamFactory("params", jax.random.PRNGKey(0), jnp.float32)
    params = moe.moe_init(p, cfg)
    # bias router hard toward expert 0 -> must overflow capacity
    params["router"] = params["router"].at[:, 0].set(50.0)
    h = jax.random.normal(jax.random.PRNGKey(1), (1, 64, cfg.d_model))
    out, aux = moe.moe_forward(params, h, cfg)
    assert float(aux["moe_drop_frac"]) > 0.2


def test_moe_decode_matches_forward_when_no_drops():
    cfg = dataclasses.replace(_cfg("olmoe-1b-7b"), capacity_factor=8.0)
    p = common.ParamFactory("params", jax.random.PRNGKey(0), jnp.float32)
    params = moe.moe_init(p, cfg)
    h = jax.random.normal(jax.random.PRNGKey(2), (4, 1, cfg.d_model)) * 0.5
    dec = moe.moe_decode(params, h, cfg)
    fwd, _ = moe.moe_forward(params, h, cfg)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fwd),
                               atol=1e-4, rtol=1e-4)


def test_ring_pack_kv_layout():
    S, L = 10, 4
    k = jnp.arange(S, dtype=jnp.float32).reshape(1, S, 1, 1)
    kp, _ = attention.ring_pack_kv(k, k, L)
    # slot s holds latest pos p <= 9 with p % 4 == s: [8, 9, 6, 7]
    np.testing.assert_array_equal(np.asarray(kp).reshape(-1), [8, 9, 6, 7])
