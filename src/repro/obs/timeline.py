"""Precision-timeline recorder: the paper's bitlength trajectories, live.

Two entry kinds share one JSONL stream, discriminated by ``kind``:

``train`` — one entry per recorded train step, the per-layer
``PrecisionDecision`` (man/exp bits) the policy holds at that step::

    {"kind": "train", "step": 40,
     "layers": [{"layer": 0, "man_bits": 3, "exp_bits": 5}, ...]}

``serve`` — one entry per scheduler step: which dense geometry holds how
many pool blocks/bytes right now, plus occupancy and the pressure
controller's state. The byte figures are computed from the same per-slot
rates `BlockPool` charges, so ``sum(geometry_bytes.values()) ==
used_bytes`` holds exactly (the acceptance criterion's byte-agreement)::

    {"kind": "serve", "step": 12, "geometry_blocks": {"sfp-m3e5": 6},
     "geometry_bytes": {"sfp-m3e5": 98304}, "used_bytes": 98304,
     "free_bytes": ..., "capacity_bytes": ..., "occupancy": 0.43,
     "pressure": "degraded", "quarantined": 0, "running": 2}

This replaces the post-hoc reconstruction in ``fig_qm_bitlengths.py``
for live runs: the figure script can consume this stream directly.
"""
from __future__ import annotations

import json
import time
from typing import IO, Any, Iterable


class PrecisionTimeline:
    def __init__(self, path: str | None = None,
                 truncate: bool = True) -> None:
        self.path = path
        self.entries: list[dict[str, Any]] = []
        self._fh: IO[str] | None = None
        if path:
            self._fh = open(path, "w" if truncate else "a")

    def _push(self, entry: dict[str, Any]) -> None:
        self.entries.append(entry)
        if self._fh is not None:
            self._fh.write(json.dumps(entry) + "\n")
            self._fh.flush()

    def record_train(self, step: int,
                     decisions: Iterable[tuple[int, int]]) -> None:
        """``decisions``: per-layer (man_bits, exp_bits), policy order."""
        self._push({
            "kind": "train", "ts": time.time(), "step": int(step),
            "layers": [{"layer": i, "man_bits": int(m), "exp_bits": int(e)}
                       for i, (m, e) in enumerate(decisions)]})

    def record_serve(self, step: int, *,
                     geometry_blocks: dict[str, int],
                     geometry_bytes: dict[str, int],
                     used_bytes: int, free_bytes: int, capacity_bytes: int,
                     occupancy: float, pressure: str,
                     quarantined: int, running: int) -> None:
        self._push({
            "kind": "serve", "ts": time.time(), "step": int(step),
            "geometry_blocks": {k: int(v)
                                for k, v in geometry_blocks.items()},
            "geometry_bytes": {k: int(v)
                               for k, v in geometry_bytes.items()},
            "used_bytes": int(used_bytes), "free_bytes": int(free_bytes),
            "capacity_bytes": int(capacity_bytes),
            "occupancy": float(occupancy), "pressure": str(pressure),
            "quarantined": int(quarantined), "running": int(running)})

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None
