"""Architecture + shape configuration system.

Every assigned architecture is a frozen ArchConfig; reduced() derives the
CPU smoke-test variant of the same family. input_specs() produces
ShapeDtypeStruct stand-ins for the dry-run (no allocation).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# Layer kinds usable in a period pattern.
GLOBAL = "global"   # full causal attention
LOCAL = "local"     # sliding-window attention
SSD = "ssd"         # mamba2 state-space duality block
RGLRU = "rglru"     # Griffin RG-LRU recurrent block


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                  # dense|moe|ssm|hybrid|audio|vlm|cnn
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    period: Tuple[str, ...]      # repeating layer-kind pattern
    # attention
    window: int = 4096
    attn_softcap: Optional[float] = None
    final_softcap: Optional[float] = None
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    head_dim: Optional[int] = None
    # mlp
    act: str = "silu"
    glu: bool = True
    # moe
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    capacity_factor: float = 1.25
    # ssm (mamba2)
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_groups: int = 1
    conv_width: int = 4
    # rglru (griffin)
    lru_width: Optional[int] = None
    # multimodal stub frontend
    prefix_tokens: int = 0       # precomputed frame/patch embeddings
    # misc
    tie_embeddings: bool = True
    emb_scale: bool = False
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 128
    source: str = ""

    # ---- derived ----
    @property
    def head_dim_(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // max(self.n_heads, 1)

    @property
    def padded_vocab(self) -> int:
        m = self.vocab_pad_multiple
        return ((self.vocab + m - 1) // m) * m

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def remainder(self) -> Tuple[str, ...]:
        return self.period[: self.n_layers % len(self.period)]

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def lru_width_(self) -> int:
        return self.lru_width if self.lru_width else self.d_model

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + per-layer)."""
        d, hd = self.d_model, self.head_dim_
        n = self.padded_vocab * d  # embed (tied head)
        if not self.tie_embeddings:
            n += self.padded_vocab * d
        per_kind = {}
        attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd + self.n_heads * hd * d
        if self.is_moe:
            mlp = d * self.n_experts + self.n_experts * (
                (2 if self.glu else 1) * d * self.d_ff_expert + self.d_ff_expert * d)
        else:
            mlp = (2 if self.glu else 1) * d * self.d_ff + self.d_ff * d
        per_kind[GLOBAL] = attn + mlp + 2 * d
        per_kind[LOCAL] = attn + mlp + 2 * d
        di, N, H = self.d_inner, self.ssm_state, self.ssm_heads
        per_kind[SSD] = (d * (2 * di + 2 * self.ssm_groups * N + H) + di * d
                         + 3 * H + 2 * d + di)
        lw = self.lru_width_
        per_kind[RGLRU] = d * 2 * lw + lw * d + 2 * lw * lw + 3 * lw + 2 * d + mlp
        for i in range(self.n_layers):
            kind = self.period[i % len(self.period)]
            n += per_kind[kind]
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k of n_experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        full_moe = self.n_experts * ((2 if self.glu else 1) * d * self.d_ff_expert
                                     + self.d_ff_expert * d)
        active_moe = self.top_k * ((2 if self.glu else 1) * d * self.d_ff_expert
                                   + self.d_ff_expert * d)
        return self.param_count() - self.n_layers * (full_moe - active_moe)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

# Archs allowed to run long_500k (sub-quadratic: SSM / hybrid-with-local-attn).
LONG_CONTEXT_OK = ("mamba2-370m", "recurrentgemma-9b")


def cells_for(arch: "ArchConfig"):
    """The (shape) cells this arch runs in the dry-run matrix."""
    names = ["train_4k", "prefill_32k", "decode_32k"]
    if arch.name in LONG_CONTEXT_OK:
        names.append("long_500k")
    return [SHAPES[n] for n in names]


def input_specs(arch: ArchConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input (dry-run, no alloc).

    train/prefill: full-sequence token batch (+labels for train).
    decode: one new token per sequence (the KV cache is part of serve state,
    built separately by serve.engine.cache_specs).
    """
    B, S = shape.global_batch, shape.seq_len
    specs = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), jnp.int32)
    else:  # decode
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    if arch.prefix_tokens > 0 and shape.kind != "decode":
        specs["cond_embeddings"] = jax.ShapeDtypeStruct(
            (B, arch.prefix_tokens, arch.d_model), arch.compute_dtype)
    return specs


_REGISTRY: Dict[str, ArchConfig] = {}


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get(name: str) -> ArchConfig:
    from repro import configs as _c  # ensure registration side effects ran
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch '{name}'; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def names():
    from repro import configs as _c  # noqa: F401
    return sorted(_REGISTRY)


def reduced(cfg: ArchConfig, *, n_layers: Optional[int] = None,
            d_model: int = 128, seq: int = 64) -> ArchConfig:
    """A tiny same-family variant for CPU smoke tests."""
    period = cfg.period
    nl = n_layers if n_layers is not None else max(len(period), 2)
    n_heads = max(2, min(cfg.n_heads, 4))
    kv = max(1, min(cfg.n_kv_heads, n_heads))
    changes = dict(
        name=cfg.name + "-reduced",
        n_layers=nl,
        d_model=d_model,
        n_heads=n_heads,
        n_kv_heads=kv,
        head_dim=d_model // n_heads,
        d_ff=d_model * 3,
        vocab=512,
        window=min(cfg.window, max(seq // 2, 8)),
        vocab_pad_multiple=128,
    )
    if cfg.is_moe:
        changes.update(n_experts=4, top_k=2, d_ff_expert=d_model * 2)
    if SSD in period:
        changes.update(ssm_state=16, ssm_head_dim=32, ssm_chunk=16)
    if RGLRU in period:
        changes.update(lru_width=d_model)
    if cfg.prefix_tokens:
        changes.update(prefix_tokens=8)
    return dataclasses.replace(cfg, **changes)
