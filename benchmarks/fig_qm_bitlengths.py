"""Fig 2/3/4 (+ §IV QE): learned bitlength trajectories + accuracy parity.

Generalized over the precision-policy registry: any learned policy
("qm", "qe", or the composed "qm+qe") yields per-period mantissa and/or
exponent bitlength trajectories — the paper-style per-layer collapse
figure — plus loss parity against the unquantized baseline. run()
reports the headline "qm" numbers (consumed by benchmarks/run.py) and a
"qm+qe" section with both fields' trajectories.
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from benchmarks import common


def _traj(run: Dict, key: str) -> np.ndarray:
    """(steps, periods) trajectory of one snapshot field, or empty."""
    rows = [t[key] for t in run["qm_traj"] if key in t]
    return np.asarray(rows) if rows else np.zeros((0, 0))


def policy_trajectories(policy: str) -> Dict:
    """Train under ``policy`` and summarize every learned-bitlength field."""
    r = common.lm_run(policy)
    base = common.lm_run("none")
    out = {"policy": policy, "fields": {}, "footprint": r.get("footprint")}
    for key, label in (("act", "mantissa_act"), ("w", "mantissa_w"),
                       ("act_e", "exponent_act"), ("w_e", "exponent_w")):
        t = _traj(r, key)
        if not t.size:
            continue
        out["fields"][label] = {
            "final_mean": float(t[-1].mean()),
            "final_min": float(t[-1].min()),
            "final_max": float(t[-1].max()),
            "per_layer_final": t[-1].tolist(),
            "traj_mean": t.mean(1).tolist()[::5],
        }
    out["xent"] = float(np.mean([h["xent"] for h in r["history"][-10:]]))
    out["xent_base"] = float(np.mean([h["xent"]
                                      for h in base["history"][-10:]]))
    out["xent_delta"] = out["xent"] - out["xent_base"]
    return out


def run():
    qm = policy_trajectories("qm")
    both = policy_trajectories("qm+qe")
    act = qm["fields"]["mantissa_act"]
    traj = np.asarray(act["traj_mean"])
    out = {
        # headline keys consumed by benchmarks/run.py (qm-only, as before)
        "steps_to_half": int(np.argmax(traj < 3.5)) * 5
        if (traj < 3.5).any() else -1,
        "final_act_mean": act["final_mean"],
        "final_act_min": act["final_min"],
        "final_act_max": act["final_max"],
        "final_w_mean": qm["fields"]["mantissa_w"]["final_mean"],
        "xent_qm": qm["xent"],
        "xent_base": qm["xent_base"],
        "xent_delta": qm["xent_delta"],
        "act_traj_mean": act["traj_mean"],
        # the generalized per-policy sections (exponent + mantissa fields)
        "policies": {"qm": qm, "qm+qe": both},
    }
    return out


def main():
    r = run()
    print(f"QM bits: act {r['final_act_mean']:.2f} "
          f"[{r['final_act_min']:.2f}..{r['final_act_max']:.2f}], "
          f"w {r['final_w_mean']:.2f}; reached <3.5b at step "
          f"{r['steps_to_half']}")
    print(f"loss parity: qm {r['xent_qm']:.3f} vs base {r['xent_base']:.3f} "
          f"(delta {r['xent_delta']:+.3f})")
    print("mean-act-bits trajectory (every 5 steps):",
          [f"{x:.1f}" for x in r["act_traj_mean"]])
    both = r["policies"]["qm+qe"]
    for label, f in both["fields"].items():
        print(f"qm+qe {label}: final {f['final_mean']:.2f} "
              f"[{f['final_min']:.2f}..{f['final_max']:.2f}] "
              f"per-layer {['%.1f' % v for v in f['per_layer_final']]}")
    if both.get("footprint"):
        fp = both["footprint"]
        print(f"qm+qe modeled stash: {fp['bits_per_value']:.2f} b/value "
              f"({100 * fp['vs_bf16']:.1f}% of BF16, "
              f"{100 * fp['vs_fp32']:.1f}% of FP32) — "
              f"man {fp['man_bits']:.2f}b + exp {fp['exp_bits']:.2f}b + sign")
    print(f"qm+qe loss parity: {both['xent']:.3f} vs base "
          f"{both['xent_base']:.3f} (delta {both['xent_delta']:+.3f})")
    return r


if __name__ == "__main__":
    main()
