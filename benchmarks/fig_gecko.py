"""Fig 9/10: exponent value distribution + post-Gecko bitlength CDF +
compression ratios for weights and activations of a trained model."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import containers, gecko


def run():
    r = common.lm_run("none")
    params = r["params"]
    # weights: biggest 2D tensors
    weights = [jnp.asarray(v) for v in jax.tree.leaves(params)
               if hasattr(v, "ndim") and v.ndim >= 2][:8]
    w_exp = jnp.concatenate([containers.exponent_field(w).reshape(-1)
                             for w in weights])
    # activations: forward stash of the CNN run (post-ReLU etc.)
    crun = common.cnn_run("none")
    _, stash = common.cnn_stash(crun, "none")
    a_exp = jnp.concatenate([
        containers.exponent_field(jnp.asarray(s["tensor"])).reshape(-1)
        for s in stash[:6]])

    # Activations after ReLU are ~half exact zeros (exponent field 0),
    # which poisons delta rows. The paper combines SFP with JS-style
    # zero-skip (§VI-B, "when combined this further improves..."): one tag
    # bit per value, Gecko over the nonzero exponents only.
    a_nz = a_exp[a_exp != 0]
    out = {}
    for name, e, nz in (("weights", w_exp, None),
                        ("activations", a_exp, a_nz)):
        ratio_d = float(gecko.compression_ratio(e, "delta"))
        ratio_b = float(gecko.compression_ratio(e, "bias"))
        pv = np.asarray(gecko.per_value_bits(e, "delta"))
        centered = np.abs(np.asarray(e, np.int32) - 127)
        d = {
            "ratio_delta": ratio_d, "ratio_bias": ratio_b,
            "frac_1bit": float((pv <= 1).mean()),
            "frac_le4bit": float((pv <= 4).mean()),
            "exp_within_16_of_bias": float((centered <= 16).mean()),
        }
        if nz is not None:
            zs_bits = float(gecko.compressed_bits(nz, "delta")) + e.size
            d["ratio_delta_zeroskip"] = zs_bits / (e.size * 8)
            d["zero_frac"] = float((np.asarray(e) == 0).mean())
        out[name] = d
    return out


def main():
    r = run()
    for name, d in r.items():
        print(f"{name}: gecko ratio delta={d['ratio_delta']:.3f} "
              f"bias={d['ratio_bias']:.3f}; <=1b {100*d['frac_1bit']:.0f}%, "
              f"<=4b {100*d['frac_le4bit']:.0f}%; "
              f"|exp-127|<=16 for {100*d['exp_within_16_of_bias']:.0f}%")
        if "ratio_delta_zeroskip" in d:
            print(f"  with JS zero-skip (paper §VI-B combo): "
                  f"{d['ratio_delta_zeroskip']:.3f} "
                  f"(zeros: {100*d['zero_frac']:.0f}%)")
    return r


if __name__ == "__main__":
    main()
