"""CNNs for the paper-faithful reproduction (ResNet18, MobileNetV3-Small).

The paper's own evaluation targets (§VI). Training uses synthetic
clusterable images (data-free environment, DESIGN.md D1); the benchmarks
reproduce the *mechanism-level* claims: QM bitlength collapse, BitChop
trajectories, Gecko ratios, Table I footprint breakdowns, and the Fig 13
comparison against JS / GIST++ (which need the ReLU/pool structure CNNs
provide).

``forward(..., collect_stash=True)`` returns every stashed activation with
its (signless, relu_pool) tags so core.footprint can account each tensor
exactly as the paper's Table I does.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro import policies
from repro.models import common


@dataclasses.dataclass(frozen=True)
class CNNConfig:
    name: str = "resnet18"
    arch: str = "resnet"          # 'resnet' | 'mobilenetv3'
    stages: Tuple[int, ...] = (2, 2, 2, 2)
    widths: Tuple[int, ...] = (64, 128, 256, 512)
    stem_width: int = 64
    n_classes: int = 1000
    img_size: int = 224
    in_ch: int = 3
    dtype: str = "float32"

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)


RESNET18 = CNNConfig()
RESNET8 = CNNConfig(name="resnet8", stages=(1, 1, 1), widths=(16, 32, 64),
                    stem_width=16, n_classes=10, img_size=32)
MOBILENETV3_SMALL = CNNConfig(
    name="mobilenetv3-small", arch="mobilenetv3",
    stages=(1, 2, 3, 2, 3), widths=(16, 24, 40, 96, 576),
    stem_width=16, n_classes=1000, img_size=224)


def conv_init(p: common.ParamFactory, kh, kw, cin, cout):
    return p((kh, kw, cin, cout), (None, None, None, None),
             scale=(kh * kw * cin) ** -0.5)


def conv(x, w, stride=1, groups=1):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def norm_init(p, c):
    return {"scale": p((c,), (None,), init="ones", dtype=jnp.float32),
            "bias": p((c,), (None,), init="zeros", dtype=jnp.float32)}


def norm(params, x):
    # Group-less "batch-norm free" norm: per-channel affine over layer stats
    # (synthetic-data training; avoids running-stat plumbing).
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=(1, 2), keepdims=True)
    var = jnp.var(xf, axis=(1, 2), keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + 1e-5)
    return (y * params["scale"] + params["bias"]).astype(x.dtype)


def _hswish(x):
    return x * jax.nn.relu6(x + 3.0) / 6.0


class CNN:
    def __init__(self, cfg: CNNConfig, policy=None):
        self.cfg = cfg
        self.policy = policies.coerce(policy)
        self.dims = policies.ScopeDims.for_dtype(cfg.compute_dtype)

    # -- init ----------------------------------------------------------

    def init(self, key) -> Any:
        p = common.ParamFactory(common.MODE_PARAMS, key,
                                self.cfg.compute_dtype)
        return (self._init_resnet(p) if self.cfg.arch == "resnet"
                else self._init_mnv3(p))

    def _init_resnet(self, p):
        cfg = self.cfg
        params = {"stem": {"w": conv_init(p, 3, 3, cfg.in_ch, cfg.stem_width),
                           "n": norm_init(p, cfg.stem_width)}}
        cin = cfg.stem_width
        for si, (n_blocks, cout) in enumerate(zip(cfg.stages, cfg.widths)):
            for bi in range(n_blocks):
                stride = 2 if (bi == 0 and si > 0) else 1
                blk = {
                    "c1": conv_init(p, 3, 3, cin, cout),
                    "n1": norm_init(p, cout),
                    "c2": conv_init(p, 3, 3, cout, cout),
                    "n2": norm_init(p, cout),
                }
                if stride != 1 or cin != cout:
                    blk["proj"] = conv_init(p, 1, 1, cin, cout)
                params[f"s{si}b{bi}"] = blk
                cin = cout
        params["fc"] = p((cin, cfg.n_classes), (None, None))
        return params

    def _init_mnv3(self, p):
        cfg = self.cfg
        params = {"stem": {"w": conv_init(p, 3, 3, cfg.in_ch, cfg.stem_width),
                           "n": norm_init(p, cfg.stem_width)}}
        cin = cfg.stem_width
        for si, (n_blocks, cout) in enumerate(zip(cfg.stages, cfg.widths)):
            for bi in range(n_blocks):
                exp = max(cin * 3, cout)
                blk = {
                    "pw1": conv_init(p, 1, 1, cin, exp),
                    "n1": norm_init(p, exp),
                    "dw": conv_init(p, 3, 3, 1, exp),
                    "n2": norm_init(p, exp),
                    "se_r": p((exp, max(exp // 4, 8)), (None, None)),
                    "se_e": p((max(exp // 4, 8), exp), (None, None)),
                    "pw2": conv_init(p, 1, 1, exp, cout),
                    "n3": norm_init(p, cout),
                }
                params[f"s{si}b{bi}"] = blk
                cin = cout
        params["fc"] = p((cin, cfg.n_classes), (None, None))
        return params

    # -- forward -------------------------------------------------------

    def _quant(self, x, bits, key, stash, name, *, signless, relu_pool):
        """Per-layer activation quantization + stash collection.

        ``bits`` drives the policy externally (the CNN benchmark loop owns
        its own per-site bitlength state): a scalar, a {site: value} dict,
        or a {site: slice-dict} dict for multi-field policies (BitWave's
        {"act": man, "act_e": exp}). Policies that need a bitlength input
        are skipped when none is provided — matching the pre-registry
        behaviour.
        """
        pol = self.policy
        if pol.enabled:
            b = bits[name] if isinstance(bits, dict) else bits
            if b is not None or not pol.requires_act_bits:
                pslice = b if isinstance(b, dict) else {"act": b}
                x = pol.quantize_act(x, pslice, key, self.dims)
        if stash is not None:
            stash.append({"name": name, "tensor": x, "signless": signless,
                          "relu_pool": relu_pool})
        return x

    def forward(self, params, images, *, act_bits=None, key=None,
                collect_stash: bool = False
                ) -> Tuple[jax.Array, Optional[List[Dict]]]:
        cfg = self.cfg
        key = key if key is not None else jax.random.PRNGKey(0)
        stash: Optional[List[Dict]] = [] if collect_stash else None
        k_i = iter(jax.random.split(key, 256))

        x = images.astype(cfg.compute_dtype)
        x = conv(x, params["stem"]["w"], stride=1 if cfg.img_size <= 64 else 2)
        x = jax.nn.relu(norm(params["stem"]["n"], x))
        x = self._quant(x, act_bits, next(k_i), stash, "stem", signless=True,
                        relu_pool=False)

        if cfg.arch == "resnet":
            for si in range(len(cfg.stages)):
                for bi in range(cfg.stages[si]):
                    blk = params[f"s{si}b{bi}"]
                    stride = 2 if (bi == 0 and si > 0) else 1
                    r = x
                    y = jax.nn.relu(norm(blk["n1"], conv(x, blk["c1"], stride)))
                    y = self._quant(y, act_bits, next(k_i), stash,
                                    f"s{si}b{bi}.a1", signless=True,
                                    relu_pool=False)
                    y = norm(blk["n2"], conv(y, blk["c2"]))
                    if "proj" in blk:
                        r = conv(r, blk["proj"], stride)
                    x = jax.nn.relu(y + r)
                    x = self._quant(x, act_bits, next(k_i), stash,
                                    f"s{si}b{bi}.out", signless=True,
                                    relu_pool=False)
        else:
            for si in range(len(cfg.stages)):
                for bi in range(cfg.stages[si]):
                    blk = params[f"s{si}b{bi}"]
                    stride = 2 if (bi == 0 and si > 0) else 1
                    y = _hswish(norm(blk["n1"], conv(x, blk["pw1"])))
                    y = self._quant(y, act_bits, next(k_i), stash,
                                    f"s{si}b{bi}.exp", signless=False,
                                    relu_pool=False)
                    y = _hswish(norm(blk["n2"], conv(y, blk["dw"], stride,
                                                     groups=y.shape[-1])))
                    se = jnp.mean(y.astype(jnp.float32), axis=(1, 2))
                    se = jax.nn.sigmoid(
                        jax.nn.relu(se @ params[f"s{si}b{bi}"]["se_r"]
                                    .astype(jnp.float32))
                        @ params[f"s{si}b{bi}"]["se_e"].astype(jnp.float32))
                    y = y * se[:, None, None, :].astype(y.dtype)
                    y = norm(blk["n3"], conv(y, blk["pw2"]))
                    x = y + x if y.shape == x.shape else y
                    x = self._quant(x, act_bits, next(k_i), stash,
                                    f"s{si}b{bi}.out", signless=False,
                                    relu_pool=False)

        # global average pool (a pooled-after-ReLU tensor for GIST++)
        pooled = jnp.mean(x.astype(jnp.float32), axis=(1, 2))
        if stash is not None:
            stash.append({"name": "pool", "tensor": pooled,
                          "signless": True, "relu_pool": cfg.arch == "resnet"})
        logits = pooled @ params["fc"].astype(jnp.float32)
        return logits, stash

    def loss(self, params, batch, *, act_bits=None, key=None):
        logits, _ = self.forward(params, batch["images"], act_bits=act_bits,
                                 key=key)
        labels = batch["labels"]
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
        acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
        return nll, {"xent": nll, "acc": acc}


def synthetic_images(key, n: int, cfg: CNNConfig):
    """Clusterable images: class-conditional gaussian blobs + noise.

    Class prototypes come from a FIXED seed (they define the task); the
    per-call key only draws labels and noise.
    """
    k1, k3 = jax.random.split(key, 2)
    labels = jax.random.randint(k1, (n,), 0, cfg.n_classes)
    protos = jax.random.normal(
        jax.random.PRNGKey(1234),
        (cfg.n_classes, cfg.img_size, cfg.img_size, cfg.in_ch)) * 1.2
    imgs = protos[labels] + 0.3 * jax.random.normal(
        k3, (n, cfg.img_size, cfg.img_size, cfg.in_ch))
    return {"images": imgs.astype(cfg.compute_dtype), "labels": labels}
