"""Packed flash-decode: the fused decompress-attend kernel must be
bit-exact (interpret mode) against the ref unpack-then-attend oracle, agree
with the raw-cache decode semantics (ring buffers included), and the
kvcache fused path must match the unpack fallback."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import codecs, configs
from repro.configs.base import reduced
from repro.kernels import ops, ref
from repro.kernels import packed_flash_decode as pfd
from repro.models import attention
from repro.serve import kvcache


def _packed_kv(key, B, L, D, container, dtype):
    ks = jax.random.split(key, 2)
    k = jax.random.normal(ks[0], (B, L, D), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[1], (B, L, D), jnp.float32).astype(dtype)
    f = codecs.fields_for(container, dtype)
    kp, kb = ref.sfp_pack_nd(k, f)
    vp, vb = ref.sfp_pack_nd(v, f)
    return (kp, kb, vp, vb), (k, v), f


@pytest.mark.parametrize("container,dtype", [("sfp8", jnp.bfloat16),
                                             ("sfp16", jnp.bfloat16),
                                             ("sfp16", jnp.float32)])
@pytest.mark.parametrize("rep", [1, 4])  # GQA ratio H / KH
@pytest.mark.parametrize("window,pos,L", [
    (None, 47, 48),   # global, cache full
    (None, 10, 48),   # global, partially filled (masked tail)
    (None, 39, 40),   # L not a block_l multiple: block shrinks to a divisor
    (16, 5, 16),      # local ring, not yet wrapped
    (16, 37, 16),     # local ring, wrapped slots
])
def test_kernel_bit_exact_vs_oracle(container, dtype, rep, window, pos, L):
    B, KH, hd = 2, 2, 64
    H = KH * rep
    packed, _, f = _packed_kv(jax.random.PRNGKey(0), B, L, KH * hd,
                              container, dtype)
    q = jax.random.normal(jax.random.PRNGKey(1), (B, 1, H, hd),
                          jnp.float32).astype(dtype)
    posa = jnp.asarray(pos, jnp.int32)
    got = pfd.packed_flash_decode(q, *packed, posa, fields=f, window=window,
                                  block_l=16, interpret=True)
    # Jit the oracle so XLA applies the same elementwise fusion (fma) as in
    # the compiled interpret-mode kernel — the op sequence is identical.
    oracle = jax.jit(functools.partial(ref.packed_flash_decode, fields=f,
                                       window=window, block_l=16))
    want = oracle(q, *packed, posa)
    np.testing.assert_array_equal(np.asarray(got, np.float32),
                                  np.asarray(want, np.float32))


@pytest.mark.parametrize("kind", ["global", "local"])
def test_oracle_matches_decode_attend_semantics(kind):
    """Unpack-then-attend over the packed cache must agree with the raw
    decode path on the same (ring-buffered) slot semantics."""
    cfg = dataclasses.replace(reduced(configs.get("gemma3-12b")),
                              dtype="float32")
    B, hd, KH, H = 2, cfg.head_dim_, cfg.n_kv_heads, cfg.n_heads
    D = KH * hd
    L = 16 if kind == "local" else 24
    cfg = dataclasses.replace(cfg, window=L)  # ring covers the window
    container, dtype = "sfp16", jnp.float32
    pos = jnp.asarray(L + 5 if kind == "local" else L - 2, jnp.int32)
    packed, (k, v), f = _packed_kv(jax.random.PRNGKey(2), B, L, D,
                                   container, dtype)
    q = jax.random.normal(jax.random.PRNGKey(3), (B, 1, H, hd), jnp.float32)
    window = L if kind == "local" else None
    got = ref.packed_flash_decode(q, *packed, pos, f, window=window)
    k_c = ref.sfp_unpack_nd(packed[0], packed[1], dtype, f
                            ).reshape(B, L, KH, hd)
    v_c = ref.sfp_unpack_nd(packed[2], packed[3], dtype, f
                            ).reshape(B, L, KH, hd)
    want = attention.decode_attend(q, k_c, v_c, pos, cfg, kind)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("container", ["sfp8", "sfp16"])
def test_kvcache_fused_matches_unpack_fallback(container):
    """attention_decode_packed: the fused kernel path (interpret backend)
    and the whole-cache unpack fallback (ref backend) must produce the
    same outputs and identical packed caches."""
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    model_params = _attn_params(cfg)
    B, L = 2, 12
    h_tok = 0.3 * jax.random.normal(jax.random.PRNGKey(4),
                                    (B, 1, cfg.d_model), jnp.float32)
    outs, caches = {}, {}
    for backend in ("ref", "interpret"):
        ops.force_backend(backend)
        try:
            cache = kvcache.packed_cache_init(cfg, "global", B, L, container)
            pos = jnp.asarray(0, jnp.int32)
            out, cache = kvcache.attention_decode_packed(
                model_params, h_tok, cache, pos, cfg, kind="global",
                container=container)
        finally:
            ops.force_backend(None)
        outs[backend] = np.asarray(out)
        caches[backend] = jax.tree.map(np.asarray, cache)
    np.testing.assert_allclose(outs["interpret"], outs["ref"],
                               atol=1e-5, rtol=1e-5)
    for part in ("payload", "bases"):  # same splice, same packed bits
        np.testing.assert_array_equal(caches["interpret"].k.data[part],
                                      caches["ref"].k.data[part])
        np.testing.assert_array_equal(caches["interpret"].v.data[part],
                                      caches["ref"].v.data[part])


def test_local_ring_slot_fused_decode():
    """Fused decode over a wrapped local ring buffer: slots written via
    splice at decode positions past the window must stay valid/invalid
    exactly as in the raw ring cache."""
    cfg = dataclasses.replace(reduced(configs.get("gemma3-12b")),
                              dtype="float32", window=8)
    params = _attn_params(cfg)
    B, L = 1, 8  # L == window: ring exactly covers the window
    raw = attention.cache_init(cfg, "local", B, L, jnp.float32)
    packed = kvcache.packed_cache_init(cfg, "local", B, L, "sfp16")
    outs_raw, outs_pk = [], []
    ops.force_backend("interpret")
    try:
        for t in range(12):  # wraps the 8-slot ring
            h_tok = 0.3 * jax.random.normal(jax.random.PRNGKey(10 + t),
                                            (B, 1, cfg.d_model), jnp.float32)
            pos = jnp.asarray(t, jnp.int32)
            o_raw, raw = attention.attention_decode(params, h_tok, raw, pos,
                                                    cfg, kind="local")
            o_pk, packed = kvcache.attention_decode_packed(
                params, h_tok, packed, pos, cfg, kind="local",
                container="sfp16")
            outs_raw.append(np.asarray(o_raw))
            outs_pk.append(np.asarray(o_pk))
    finally:
        ops.force_backend(None)
    # sfp16 keeps 10 fp32 mantissa bits: decode outputs track closely even
    # after the ring wraps (would diverge wildly on a slot-semantics bug).
    for t, (a, b) in enumerate(zip(outs_raw, outs_pk)):
        np.testing.assert_allclose(a, b, atol=5e-3, rtol=5e-3)


def test_packed_cache_axes_pair_with_cache_tree():
    """engine.cache_axes must build packed axes from the real (batch,
    max_len): PackedTensor carries its logical shape as pytree aux data,
    so an axes tree built from placeholder dims could never be paired
    leaf-for-leaf with the cache (sharded serving, dryrun lowering)."""
    from repro.models.model import DecoderModel
    from repro.serve import engine
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    model = DecoderModel(cfg, kv_container="sfp8")
    spec = model.init_cache(2, 16, spec_only=True)
    axes = engine.cache_axes(model, 2, 16)
    is_axes = lambda a: isinstance(a, tuple) and all(
        x is None or isinstance(x, str) for x in a)
    assert (jax.tree.structure(axes, is_leaf=is_axes)
            == jax.tree.structure(spec))


def test_packed_cache_alloc_rounds_to_kernel_blocks():
    """Allocations past one flash-decode block round up to a block
    multiple (the kernel's no-pad blocking would otherwise shrink to a
    divisor of L — pathological for awkward lengths); small caches stay
    exact."""
    cfg = dataclasses.replace(reduced(configs.get("mistral-large-123b")),
                              dtype="float32")
    assert ops.DECODE_BLOCK_L == 128
    spec = kvcache.packed_cache_spec(cfg, "global", 1, 200)
    assert spec.k.shape[1] == 256
    spec = kvcache.packed_cache_spec(cfg, "global", 1, 64)
    assert spec.k.shape[1] == 64


def test_codec_pack_fields():
    assert codecs.get("sfp8").pack_fields(jnp.bfloat16).payload_bits == 8
    assert codecs.get("sfp16").pack_fields(jnp.float32).man_keep == 10
    assert codecs.get("bit_exact").pack_fields(jnp.bfloat16) is None
    assert codecs.get("gecko8").pack_fields(jnp.bfloat16) is None


def _attn_params(cfg):
    from repro.models import common
    p = common.ParamFactory(common.MODE_PARAMS, jax.random.PRNGKey(0),
                            jnp.float32)
    return attention.attn_init(p, cfg)
