import jax
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.distributed import sharding as shd


def _mesh():
    dev = np.asarray(jax.devices()[:1] * 4).reshape(2, 2) \
        if len(jax.devices()) < 4 else np.asarray(jax.devices()[:4]).reshape(2, 2)
    return Mesh(dev, ("data", "model"))


def test_spec_from_axes_basic():
    rules = {"embed": ("data",), "ff": ("model",), "batch": ("data",)}
    spec = shd.spec_from_axes(("embed", "ff"), rules)
    assert spec == P("data", "model")


def test_spec_axis_used_once():
    rules = {"a": ("model",), "b": ("model",)}
    spec = shd.spec_from_axes(("a", "b"), rules)
    assert spec == P("model", None)  # later dim falls back to replicated


def test_tp_layout_shards_expected_dims():
    mesh = _mesh()
    rules = shd.rules_for(mesh, layout="tp")
    assert rules["heads"] == ("model",)
    assert rules["vocab"] == ("model",)
    assert rules["batch"] == ("data",)


def test_fsdp_layout_moves_weights_to_both_axes():
    mesh = _mesh()
    rules = shd.rules_for(mesh, layout="fsdp")
    assert rules["heads"] is None
    assert rules["embed"] == ("data", "model")
    assert rules["batch"] == ("data", "model")
    assert rules["experts"] == ("model",)  # EP survives the layout switch


def test_refine_drops_indivisible_dims():
    mesh = _mesh()
    shapes = jax.ShapeDtypeStruct((3, 8), jax.numpy.float32)
    sh = jax.sharding.NamedSharding(mesh, P("data", "model"))
    out = shd.refine_shardings(shapes, sh, mesh)
    assert out.spec == P(None, "model")  # 3 % 2 != 0 -> dropped


def test_hint_noop_without_mesh():
    shd.set_active_mesh(None)
    x = jax.numpy.ones((4, 4))
    assert shd.hint(x, "data") is x
