"""Precision-policy registry: the one place adaptation strategies live.

The paper adapts floating-point containers along three axes — which
datatype, on which tensor, and over time. A ``Policy`` is one strategy for
answering those questions: it owns a state pytree (learned bitlength
parameters and/or controller registers), decides a per-tensor-scope
``PrecisionDecision{man_bits, exp_bits}`` inside the jitted train step,
quantizes activations/weights differentiably, and updates its state from
gradients (``update_learn``) and/or the loss signal (``observe``).

Mirrors the ``codecs`` registry design (PR 1): policies register under a
name, every consumer — the decoder model's stash/weight paths, the train
step, launchers, benchmarks — resolves strategies through ``get()``, and
``"a+b"`` names compose policies (e.g. ``"qm+qe"`` learns mantissa AND
exponent bitlengths in one run). Nothing outside this package dispatches
on policy mode strings.

State layout contract: ``PolicyState(learn, ctrl)`` where ``learn`` is the
differentiable pytree (fed to ``jax.grad`` alongside the model params and
SGD-updated by the policy) and ``ctrl`` is the non-differentiable
controller pytree (updated once per step from the observed loss). Scope
views handed to the model carry an ``"act"``/``"w"`` leaf per tensor
group; the model never looks inside them — it only forwards them to the
policy's methods.
"""
from __future__ import annotations

import abc
import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import containers


class PrecisionDecision(NamedTuple):
    """Integer bitlengths for one tensor scope this step (both traced)."""

    man_bits: jax.Array  # () int32, mantissa bits to keep
    exp_bits: jax.Array  # () int32, exponent bits to keep


class PolicyState(NamedTuple):
    """Everything a policy carries between steps.

    ``learn``: differentiable pytree (bitlength parameters); ``ctrl``:
    controller pytree (loss EMAs, integer bitlengths, step counters).
    Either may be an empty dict. Checkpointed generically as part of
    TrainState.
    """

    learn: Any
    ctrl: Any


@dataclasses.dataclass(frozen=True)
class ScopeDims:
    """Static scope geometry + container limits a policy sizes itself to."""

    n_periods: int
    n_rem: int
    man_bits: int  # source container mantissa bits (7 bf16, 23 fp32)
    exp_bits: int  # source container exponent bits (8 bf16/fp32)

    @classmethod
    def for_dtype(cls, dtype, n_periods: int = 0, n_rem: int = 0
                  ) -> "ScopeDims":
        spec = containers.spec_for(dtype)
        return cls(n_periods=n_periods, n_rem=n_rem,
                   man_bits=spec.man_bits, exp_bits=spec.exp_bits)


def full_decision(dims: ScopeDims) -> PrecisionDecision:
    return PrecisionDecision(
        man_bits=jnp.asarray(dims.man_bits, jnp.int32),
        exp_bits=jnp.asarray(dims.exp_bits, jnp.int32))


@jax.custom_vjp
def _ste_truncate(x, n):
    return containers.truncate_mantissa(x, n)


_ste_truncate.defvjp(lambda x, n: (containers.truncate_mantissa(x, n), None),
                     lambda _, g: (g, None))


@jax.custom_vjp
def _ste_truncate_exp(x, e):
    return containers.truncate_exponent(x, e)


_ste_truncate_exp.defvjp(
    lambda x, e: (containers.truncate_exponent(x, e), None),
    lambda _, g: (g, None))


def ste_truncate(x: jax.Array, n) -> jax.Array:
    """Mantissa truncation with a straight-through gradient (§IV-A1)."""
    return _ste_truncate(x, n)


def apply_decision_ste(x: jax.Array, d: PrecisionDecision,
                       dims: ScopeDims, *, adapts_exponent: bool
                       ) -> jax.Array:
    """Realize a decision on a tensor, straight-through in x.

    The exponent truncation is skipped entirely for mantissa-only policies
    (``adapts_exponent`` is static) so their compute graphs — and hence
    their quantized values — are bit-identical to the pre-registry
    implementations.
    """
    x = _ste_truncate(x, d.man_bits)
    if adapts_exponent:
        x = _ste_truncate_exp(x, d.exp_bits)
    return x


@dataclasses.dataclass(frozen=True)
class Policy(abc.ABC):
    """One precision-adaptation strategy; instances are static jit closures.

    Frozen/hashable: hyper-parameters ride on the instance (the registry
    stores classes; ``get(name, **overrides)`` constructs). All methods
    are pure pytree functions, safe inside jit/scan/grad.
    """

    container: str = "sfp8"        # realized stash container (codec name)
    quantize_weights: bool = True  # weight-side fake-quant at use sites

    # Class attributes, not dataclass fields (no annotations on purpose):
    name = "?"
    enabled = True            # False -> model skips all hooks
    adapts_exponent = False   # True -> stash/STE apply exponent truncation
    has_stash_grad = False    # True -> stash-side bitlength estimator
    requires_act_bits = False  # CNN path: skip when no bits are provided

    @property
    def quantizes_weights(self) -> bool:
        """Effective weight-side switch (controller policies override)."""
        return self.enabled and self.quantize_weights

    # -- state ----------------------------------------------------------

    def init_state(self, dims: ScopeDims) -> PolicyState:
        return PolicyState(learn={}, ctrl={})

    # -- views threaded through the jitted step --------------------------

    def control_view(self, ctrl: Any, dims: ScopeDims) -> Any:
        """Decision inputs derived from controller state (outside grad)."""
        return {}

    def forward_view(self, learn: Any, cview: Any, dims: ScopeDims) -> Any:
        """The per-forward pytree the model threads (RunState.pol).

        ``learn`` must pass through untouched wherever it is used so that
        jax.grad w.r.t. learn sees the forward's uses of it.
        """
        return {}

    def scan_slices(self, view: Any, dims: ScopeDims) -> Any:
        """Per-period slices: a pytree with leading dim n_periods."""
        return {}

    def rem_slice(self, view: Any, i: int, dims: ScopeDims) -> Any:
        """The scope view of remainder layer ``i``."""
        return {}

    # -- in-step decisions & quantizers ----------------------------------

    def act_decision(self, pslice: Any, key: jax.Array, dims: ScopeDims
                     ) -> PrecisionDecision:
        """Resolve the activation decision for one scope (may draw once)."""
        return full_decision(dims)

    def quantize_act(self, x: jax.Array, pslice: Any, key: jax.Array,
                     dims: ScopeDims) -> jax.Array:
        """Differentiable activation quantization at a use site (CNN path:
        gradients flow to the bitlength parameters where the policy learns
        them)."""
        return x

    def quantize_weight(self, w: jax.Array, pslice: Any, key: jax.Array,
                        dims: ScopeDims) -> jax.Array:
        """Differentiable weight fake-quant at the use site."""
        return w

    def stash_grad(self, dh: jax.Array, h_q: jax.Array, pslice: Any,
                   dims: ScopeDims) -> Any:
        """Bitlength cotangents estimated from the realized stash.

        Returns a pytree matching ``pslice`` (float leaves; zeros where no
        estimate applies). Only called when ``has_stash_grad``.
        """
        return jax.tree.map(lambda a: jnp.zeros((), jnp.float32), pslice)

    # -- loss & per-step state updates -----------------------------------

    def penalty(self, learn: Any, lam: Dict[str, jax.Array], step: jax.Array,
                dims: ScopeDims) -> jax.Array:
        """Footprint-regularizer term added to the loss (eq. 7)."""
        return jnp.zeros((), jnp.float32)

    def update_learn(self, learn: Any, grads: Any, dims: ScopeDims) -> Any:
        """Apply accumulated gradients to the learned parameters."""
        return learn

    def observe(self, ctrl: Any, loss: jax.Array, lr_changed,
                dims: ScopeDims) -> Any:
        """Controller step fed by the (pre-penalty) loss (eq. 8-9)."""
        return ctrl

    # -- reporting --------------------------------------------------------

    def metrics(self, state: PolicyState, dims: ScopeDims
                ) -> Dict[str, jax.Array]:
        """Scalar metrics merged into the train-step metrics dict."""
        return {}

    def snapshot(self, state: PolicyState) -> Dict[str, Any]:
        """Host-side trajectory record (arrays allowed; benchmarks/figures)."""
        return {}

    def decision_summary(self, state: PolicyState, dims: ScopeDims
                         ) -> Dict[str, float]:
        """Mean (man_bits, exp_bits) the policy currently decides —
        deployment-style, rounded up for learned fractional bitlengths."""
        return {"man_bits": float(dims.man_bits),
                "exp_bits": float(dims.exp_bits)}

    def layer_decisions(self, state: PolicyState, dims: ScopeDims):
        """Per-period deployment decisions ``[(man_bits, exp_bits), ...]``
        (length ``dims.n_periods``) — the host-side view behind per-layer
        realized containers (``DecoderModel.stash_plan``). Policies with
        per-scope parameters override; network-wide controllers repeat
        their summary."""
        d = self.decision_summary(state, dims)
        return [(d["man_bits"], d["exp_bits"])] * dims.n_periods


def modeled_footprint(policy: Policy, state: PolicyState, dims: ScopeDims
                      ) -> Dict[str, float]:
    """Modeled stash bits/value under the policy's current decisions.

    sign + mantissa + exponent per value (metadata is negligible —
    2 scalars/scope). Exponent-bit savings from QE/BitWave show up here;
    Gecko typically compresses the remaining exponents further, so this is
    an upper bound on the realized footprint.
    """
    d = policy.decision_summary(state, dims)
    bits = 1.0 + d["man_bits"] + d["exp_bits"]
    return {
        "man_bits": d["man_bits"],
        "exp_bits": d["exp_bits"],
        "bits_per_value": bits,
        "vs_bf16": bits / 16.0,
        "vs_fp32": bits / 32.0,
    }


# ----------------------------------------------------------------------
# Registry
# ----------------------------------------------------------------------

_REGISTRY: Dict[str, type] = {}


def register(cls: type) -> type:
    """Register a Policy subclass under its ``name`` (last wins)."""
    _REGISTRY[cls.name] = cls
    return cls


def names() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def validate_name(name: str) -> Tuple[str, ...]:
    """Parse a policy name / '+'-composition *without* constructing it.

    Returns the tuple of sub-policy names; raises ``ValueError`` with a
    did-you-mean suggestion on an unknown part or a duplicate. Shared by
    the static-analysis lint rule (``repro.analysis``) and the launchers'
    argparse validators, so typos fail at the CLI/lint layer with the same
    grammar the registry enforces at construction time.
    """
    import difflib

    parts = tuple(p.strip() for p in name.split("+") if p.strip())
    if not parts:
        raise ValueError(f"empty precision-policy name {name!r}")
    for p in parts:
        if p not in _REGISTRY:
            hint = difflib.get_close_matches(p, names(), n=1, cutoff=0.5)
            msg = f"unknown precision policy {p!r}"
            if hint:
                msg += f"; did you mean {hint[0]!r}?"
            msg += (f" (registered: {list(names())}, composable with '+', "
                    f"e.g. qm+qe)")
            raise ValueError(msg)
    if len(set(parts)) != len(parts):
        raise ValueError(f"duplicate sub-policy in {name!r}")
    return parts


def _construct(name: str, kwargs: Dict[str, Any]):
    cls = _REGISTRY[name]
    fields = {f.name for f in dataclasses.fields(cls)}
    return cls(**{k: v for k, v in kwargs.items() if k in fields}), fields


def get(name: str, _strict: bool = True, **kwargs) -> Policy:
    """Resolve a policy by name; ``"a+b"`` composes.

    Keyword overrides are routed to the sub-policies that declare the
    matching dataclass field (``container`` reaches all of them); an
    override no policy consumes raises, catching typos (``_strict=False``
    drops them instead — the legacy-SFPPolicy shim path).
    """
    parts = [p.strip() for p in name.split("+") if p.strip()]
    if not parts:
        raise KeyError(f"empty policy name {name!r}")
    unknown = [p for p in parts if p not in _REGISTRY]
    if unknown:
        raise KeyError(
            f"unknown precision policy {unknown[0]!r}; registered: "
            f"{list(names())} (composable with '+')")
    if len(set(parts)) != len(parts):
        raise KeyError(f"duplicate sub-policy in {name!r}")
    built, consumed = [], set()
    for p in parts:
        pol, fields = _construct(p, kwargs)
        built.append(pol)
        consumed |= fields
    extra = set(kwargs) - consumed
    if extra and _strict:
        raise TypeError(f"policy {name!r} accepts no option(s) {sorted(extra)}")
    if len(built) == 1:
        return built[0]
    from repro.policies.composite import CompositePolicy
    return CompositePolicy(policies=tuple(built))


def coerce(policy) -> Policy:
    """Accept a Policy, a registry name, None, or a legacy SFPPolicy."""
    if policy is None:
        return get("none")
    if isinstance(policy, Policy):
        return policy
    if isinstance(policy, str):
        return get(policy)
    to_policy = getattr(policy, "to_policy", None)
    if callable(to_policy):  # legacy core.sfp.SFPPolicy shim
        return to_policy()
    raise TypeError(f"cannot interpret {policy!r} as a precision policy")
