"""Per-step overhead of each precision policy vs the `none` baseline.

The paper's methods only pay off if the adaptation machinery is cheap
relative to the step it shrinks: this benchmark times one jitted train
step of the reduced gemma2-2b config under every registry policy (and the
composed qm+qe) and reports, per policy:

  * ms/step plus the overhead ratio against the full-precision baseline,
    and
  * the *realized* packed stash bytes/step: the policy's current decision
    maps to a dense ``sfp-m{K}e{E}`` container (codecs.dense_name) and is
    priced at that geometry's true bits/value (payload planes + group
    bases) over the per-step stash volume — the same units
    BENCH_codecs.json reports, so the two artifacts agree on what a
    decision costs in bytes.

Emitted as BENCH_policies.json (repo root) standalone or via
benchmarks/run.py; the CI quick-smoke runs --quick (fewer policies, fewer
iters) on every push and the nightly emits the full sweep.
"""
from __future__ import annotations

import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp

# The full sweep covers every registered policy (so future plugins are
# picked up automatically) plus the paper's headline composition.
EXTRA_COMPOSITIONS = ("qm+qe",)
POLICIES_QUICK = ("none", "qm", "qm+qe")
ITERS = 10
ITERS_QUICK = 3
OUT = Path(__file__).resolve().parent.parent / "BENCH_policies.json"


def _median_ms(fn, iters):
    fn()  # compile
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e3


def run(quick: bool = False) -> dict:
    from repro import configs, policies
    from repro.configs.base import reduced
    from repro.data import synthetic
    from repro.models.model import DecoderModel
    from repro.optim import adamw
    from repro.optim.schedule import Schedule
    from repro.train import step as step_mod

    names = (POLICIES_QUICK if quick
             else ("none",) + tuple(n for n in policies.names()
                                    if n != "none") + EXTRA_COMPOSITIONS)
    iters = ITERS_QUICK if quick else ITERS
    cfg = reduced(configs.get("gemma2-2b"), n_layers=4, d_model=128)
    dcfg = synthetic.SyntheticConfig(vocab=cfg.vocab, seq_len=64,
                                     global_batch=8, seed=0)
    corpus = synthetic.MarkovCorpus(dcfg)
    batch = {k: jnp.asarray(v) for k, v in corpus.batch(0).items()}
    tc = step_mod.TrainConfig(
        opt=adamw.AdamWConfig(lr=5e-3),
        schedule=Schedule(total_steps=100, warmup_steps=4, base_lr=5e-3))

    from repro import codecs

    # Stash values crossing the memory boundary per step: one activation
    # tensor per scanned period plus the remainder layers.
    stash_vals = (8 * 64 * cfg.d_model
                  * (cfg.n_periods + len(cfg.remainder)))

    results = {}
    for name in names:
        model = DecoderModel(cfg, policies.get(name, container="bit_exact"))
        step = jax.jit(step_mod.make_train_step(model, tc))
        state = step_mod.init_state(model, jax.random.PRNGKey(0), tc)

        def one(state=state, step=step):
            new_state, m = step(state, batch)
            jax.block_until_ready(m["loss"])

        results[name] = {"ms_per_step": _median_ms(one, iters)}

        # Advance a few real steps so controller/learned decisions move
        # off their full-width init, then price the realized container.
        for _ in range(iters):
            state, _m = step(state, batch)
        d = model.policy.decision_summary(state.pstate, model.dims)
        if model.policy.enabled:
            container = codecs.dense_name(d["man_bits"], d["exp_bits"])
            f = codecs.fields_for(container, cfg.compute_dtype)
            bits_per_value = f.payload_bits + 8.0 / 128.0  # payload + base
        else:
            container = None
            bits_per_value = 16.0  # raw bf16 stash
        results[name].update({
            "decision": {k: float(v) for k, v in d.items()},
            "realized_container": container,
            "realized_bits_per_value": bits_per_value,
            "packed_stash_bytes_per_step": stash_vals * bits_per_value / 8,
        })

    base = results["none"]["ms_per_step"]
    base_bytes = results["none"]["packed_stash_bytes_per_step"]
    for name in names:
        results[name]["overhead_vs_none"] = (
            results[name]["ms_per_step"] / base)
        results[name]["packed_bytes_vs_none"] = (
            results[name]["packed_stash_bytes_per_step"] / base_bytes)

    return {
        "arch": cfg.name,
        "config": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                   "batch": 8, "seq": 64},
        "container": "bit_exact",
        "stash_values_per_step": stash_vals,
        "iters": iters,
        "policies": results,
    }


def main(argv=None) -> None:
    import argparse
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--quick", action="store_true",
                    help="fewer policies + iters (CI smoke)")
    args = ap.parse_args(argv)
    r = run(quick=args.quick)
    OUT.write_text(json.dumps(r, indent=2))
    print(json.dumps(r, indent=2))
    print(f"wrote {OUT}")


if __name__ == "__main__":
    main()
